"""Live corpora: incremental ingest, delta plans, and standing state.

The paper computes all-pairs PCC once over a *static* matrix; the ROADMAP
north-star is a long-lived service, and production corpora are not static
— rows arrive and get revised continuously.  This module is the streaming
side of the serving layer (docs/serving.md "Live corpora & standing
queries"):

  * **Incremental transform maintenance** — :class:`IncrementalOperand`
    keeps a (measure, dtype) prepared operand *and* the per-row running
    moments (mean, centered sum of squares M2) it derives from.  Append /
    update of d rows costs O(d·l): fresh rows seed their moments with one
    batch Welford pass, revised rows *merge* the delta into their moments
    (CoMet's "never recompute what algebra lets you update",
    arXiv:1705.08213) and rebuild only their own operand rows via
    ``Measure.from_moments``.  The merge form accumulates float drift, so
    every state carries an update counter against the corpus's drift
    budget and is periodically rebuilt exactly (``refresh``) — after a
    refresh the operand is bit-identical to a cold transform.  Rank
    measures (spearman, kendall*) have no moment form; the corpus falls
    back to a loud exact re-transform for them (serving/corpus.py).

  * **Delta-aware execution** — :class:`LiveIndex` maintains a standing
    corpus-vs-corpus result (dense matrix or per-row top-k).  On append
    of d rows only the d-vs-n rectangular grid and the d-vs-d triangle
    launch — riding the existing GridWorkload / TriangularWorkload
    bijections and reusing :class:`~repro.serving.plan_cache.PlanCache`
    entries via tile-bucketed specs — never the full (n+d) triangle.
    Delta results merge into the standing state: dense by row/column
    extension, top-k by the canonical per-row re-merge
    (:func:`~repro.core.sinks.topk_merge_rows`).  ``recovery=`` composes:
    each delta stream runs under the self-healing executor with its own
    coverage bitmap over (grid or triangular) tile ids.

  * **Versioned generations** — every mutation bumps the corpus
    generation; every standing result and served answer names the
    generation it answered against, so readers can tell a pre-delta
    answer from a post-delta one.

Standing *queries* (``CorrServer.watch``) build on the same pieces:
the server subscribes to its corpora and revalidates each watch against
each delta batch (serving/server.py).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import measures
from repro.core.allpairs import execute_plan
from repro.core.plan import needs_row_scales, prepare_operand_raw, \
    take_operand_rows
from repro.core.sinks import DenseSink, TopKSink, topk_merge_rows
from repro.serving.plan_cache import PlanCache, ProblemSpec

Array = jax.Array

# Incremental update batches an operand state may absorb before the next
# mutation triggers an exact refresh (CorpusHandle(drift_budget=...)).
DEFAULT_DRIFT_BUDGET = 64

# Pinned bound on |incremental - cold| for any result computed within one
# drift budget of moment-merged updates (tests/test_live.py property-tests
# this; the moment merge is algebraically exact, so the drift is pure f32
# rounding — observed orders of magnitude below this bound).
DRIFT_TOL = 1e-3


# ---------------------------------------------------------------------------
# Running per-row moments (Welford)
# ---------------------------------------------------------------------------


def row_moments(x: Array) -> Tuple[Array, Array]:
    """Per-row (mean, M2) with M2 = sum((x - mean)^2) — the batch form of
    Welford's accumulator (one merge of all l samples).  Seeds the moment
    state of fresh rows; numerics mirror the full transforms (mean first,
    then centered sum), so a freshly seeded row's ``from_moments`` output
    matches the cold transform."""
    x = jnp.asarray(x)
    acc = jnp.promote_types(x.dtype, jnp.float32)
    xa = x.astype(acc)
    mean = jnp.mean(xa, axis=1)
    c = xa - mean[:, None]
    m2 = jnp.sum(c * c, axis=1)
    return mean.astype(jnp.float32), m2.astype(jnp.float32)


def merge_row_moments(mean: Array, m2: Array, old_rows: Array,
                      new_rows: Array) -> Tuple[Array, Array]:
    """Welford-style delta merge: the moments of a row after replacing its
    samples, from the old moments plus the old/new sample values — O(d·l),
    no pass over unchanged state.

    Algebra (exact over the reals)::

        mean' = mean + sum(new - old) / l
        M2    = sum(x^2) - l * mean^2
        M2'   = M2 + sum(new^2 - old^2) - l * (mean'^2 - mean^2)

    In f32 the sum-of-squares form cancels catastrophically for
    low-variance rows, which is exactly the drift the corpus's drift
    budget bounds and the periodic exact refresh repairs."""
    old = jnp.asarray(old_rows).astype(jnp.float32)
    new = jnp.asarray(new_rows).astype(jnp.float32)
    l = old.shape[1]
    mean = jnp.asarray(mean, jnp.float32)
    m2 = jnp.asarray(m2, jnp.float32)
    mean2 = mean + jnp.sum(new - old, axis=1) / l
    m22 = m2 + jnp.sum(new * new - old * old, axis=1) \
        - l * (mean2 * mean2 - mean * mean)
    return mean2, jnp.maximum(m22, 0.0)


def supports_incremental(meas: measures.Measure, compute_dtype) -> bool:
    """Whether (measure, dtype) can ride the O(delta·l) moment path:
    the measure must have a moment-form transform and the dtype must not
    need per-row quantization scales (scale maintenance would re-quantize
    every row the scale of which changed — the exact path handles those)."""
    return meas.incremental and not needs_row_scales(meas, compute_dtype)


# ---------------------------------------------------------------------------
# Delta records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Delta:
    """One corpus mutation batch, as pushed to subscribers.

    kind       "append" (rows [lo, hi) are new) or "update" (rows at
               ``idx`` were replaced).
    generation the corpus generation *after* this mutation — the version
               every revalidated standing result will name.
    """

    generation: int
    kind: str
    lo: int = 0
    hi: int = 0
    idx: Optional[np.ndarray] = None

    @property
    def count(self) -> int:
        return (self.hi - self.lo) if self.kind == "append" else len(self.idx)


# ---------------------------------------------------------------------------
# Incremental operand maintenance
# ---------------------------------------------------------------------------


class IncrementalOperand:
    """A maintained prepared operand for one (measure, compute_dtype).

    State: the padded device operand (exactly what
    :func:`~repro.core.plan.prepare_operand_raw` would produce), the
    per-row running moments it derives from, and the count of moment-merge
    update batches absorbed since the last exact build.  ``append`` seeds
    fresh rows (batch Welford + ``from_moments``); ``update`` merges the
    delta into the affected rows' moments and rebuilds only those operand
    rows; both are O(delta·l) transform work.  ``refresh`` rebuilds
    exactly and zeroes the drift counter.
    """

    def __init__(self, x: Array, meas: measures.Measure, compute_dtype,
                 t: int, l_blk: int, operand: Optional[Array] = None):
        if not supports_incremental(meas, compute_dtype):
            raise ValueError(
                f"measure {meas.name!r} with compute_dtype={compute_dtype} "
                f"has no incremental (moment-form) path")
        self.meas = meas
        self.compute_dtype = compute_dtype
        self.t = int(t)
        self.l_blk = int(l_blk)
        self.update_batches = 0
        self._build(x, operand)

    def _build(self, x: Array, operand: Optional[Array] = None) -> None:
        # `operand` lets the owner hand in an already-prepared operand for
        # x (the CorpusHandle routes the initial build through its
        # TransformCache); it must be exactly prepare_operand_raw's output
        self.n, self.l = x.shape
        self.u = operand if operand is not None else prepare_operand_raw(
            x, self.meas, self.compute_dtype, self.t, self.l_blk)
        self.mean, self.m2 = row_moments(x)
        self.update_batches = 0

    @property
    def operand(self) -> Array:
        """The maintained padded operand — the drop-in ``v_pad``."""
        return self.u

    def _rows_operand(self, x_rows: Array, mean: Array, m2: Array) -> Array:
        u = self.meas.from_moments(jnp.asarray(x_rows), mean, m2, self.l,
                                   dtype=jnp.float32)
        if self.compute_dtype is not None:
            u = u.astype(self.compute_dtype)
        l_pad = self.u.shape[1]
        if u.shape[1] < l_pad:
            u = jnp.pad(u, ((0, 0), (0, l_pad - u.shape[1])))
        return u

    def append(self, x_new: Array) -> None:
        """Extend with d fresh rows: O(d·l) transform + one row concat."""
        x_new = jnp.asarray(x_new)
        d = x_new.shape[0]
        mean_d, m2_d = row_moments(x_new)
        u_d = self._rows_operand(x_new, mean_d, m2_d)
        n1 = self.n + d
        n1_pad = -(-n1 // self.t) * self.t
        u = jnp.concatenate([self.u[: self.n], u_d])
        if u.shape[0] < n1_pad:
            u = jnp.pad(u, ((0, n1_pad - u.shape[0]), (0, 0)))
        self.u = u
        self.mean = jnp.concatenate([self.mean, mean_d])
        self.m2 = jnp.concatenate([self.m2, m2_d])
        self.n = n1

    def update(self, idx: np.ndarray, x_old_rows: Array,
               x_new_rows: Array) -> None:
        """Replace rows ``idx``: Welford delta-merge of their moments plus
        an O(d·l) rebuild of just those operand rows.  Counts one drift
        batch (the merge is where f32 rounding accumulates)."""
        ji = jnp.asarray(np.asarray(idx, np.int64))
        mean2, m22 = merge_row_moments(self.mean[ji], self.m2[ji],
                                       x_old_rows, x_new_rows)
        u_rows = self._rows_operand(jnp.asarray(x_new_rows), mean2, m22)
        self.u = self.u.at[ji].set(u_rows)
        self.mean = self.mean.at[ji].set(mean2)
        self.m2 = self.m2.at[ji].set(m22)
        self.update_batches += 1

    def refresh(self, x: Array) -> None:
        """Exact rebuild from the full corpus — bit-identical to a cold
        ``prepare_operand_raw`` — and drift counter reset."""
        self._build(x)

    def stats(self) -> dict:
        return {"rows": self.n, "update_batches": self.update_batches}


# ---------------------------------------------------------------------------
# Standing top-k helpers
# ---------------------------------------------------------------------------


def topk_rows_from_dense(scores: np.ndarray, k: int,
                         col_ids: Optional[np.ndarray] = None,
                         exclude_cols: Optional[np.ndarray] = None,
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Canonical per-row top-k state from a dense (m, c) score block.

    ``col_ids`` maps local columns to global ids (default: 0..c-1);
    ``exclude_cols`` drops one global column per row (self-pairs).
    Merge order is the canonical one (|value| desc, column asc), so the
    result is bit-identical to what a TopKSink run over the same scores
    would hold."""
    scores = np.asarray(scores, np.float32)
    m, c = scores.shape
    cols = (np.arange(c, dtype=np.int64) if col_ids is None
            else np.asarray(col_ids, np.int64))
    vals = np.zeros((m, k), np.float32)
    idx = np.full((m, k), -1, np.int64)
    r_ids = np.repeat(np.arange(m, dtype=np.int64), c)
    c_ids = np.tile(cols, m)
    v = scores.reshape(-1)
    if exclude_cols is not None:
        keep = c_ids != np.repeat(np.asarray(exclude_cols, np.int64), c)
        r_ids, c_ids, v = r_ids[keep], c_ids[keep], v[keep]
    topk_merge_rows(vals, idx, r_ids, c_ids, v, k)
    return vals, idx


# ---------------------------------------------------------------------------
# LiveIndex: a standing corpus-vs-corpus result under deltas
# ---------------------------------------------------------------------------


class LiveIndex:
    """A standing all-pairs result over a live corpus.

    Subscribes to a :class:`~repro.serving.corpus.CorpusHandle` and keeps
    either the dense (n, n) similarity matrix (``k=None``) or the per-row
    top-k neighbourhood (``k=int``) current under append/update deltas:

      append(d)  launches ONLY the d-vs-n grid and the d-vs-d triangle
                 (kernel-spy asserted in tests/test_live.py) and merges —
                 dense by row/column extension, top-k by per-row re-merge.
      update(d)  launches the d-vs-n grid of the revised rows; dense
                 merges rows+columns in place; top-k rebuilds the revised
                 rows, exactly recomputes rows whose kept set referenced a
                 revised column (their k-th boundary may have moved), and
                 re-merges the new candidate values everywhere else.

    Delta plans ride the shared :class:`PlanCache` via tile-bucketed
    specs; ``recovery=`` arms the self-healing executor per delta stream
    (coverage bitmap over that stream's grid/triangle tile ids).
    ``result()`` copies always name the generation they reflect.

    Revalidation runs synchronously on the mutating thread (the corpus
    serializes mutations), so after ``corpus.append(...)`` returns the
    index is already current.
    """

    def __init__(self, corpus, *, measure: measures.MeasureLike = "pearson",
                 k: Optional[int] = None, compute_dtype=None,
                 plan_cache: Optional[PlanCache] = None,
                 max_tiles_per_pass: Optional[int] = None,
                 interpret: Optional[bool] = None, clip: bool = True,
                 fuse_epilogue: bool = True, mesh=None, recovery=None):
        if not hasattr(corpus, "subscribe"):
            from repro.serving.corpus import CorpusHandle
            corpus = CorpusHandle(corpus)
        if k is not None and k <= 0:
            raise ValueError(f"k must be positive, got {k}")
        self.corpus = corpus
        self.measure = measures.get(measure)
        self.k = k
        self.compute_dtype = compute_dtype
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self.max_tiles_per_pass = max_tiles_per_pass
        self.interpret = interpret
        self.clip = clip
        self.fuse_epilogue = fuse_epilogue
        self.mesh = mesh
        self.recovery = recovery
        self._lock = threading.Lock()
        self.deltas_applied = 0
        self.rebuilds = 0
        with self._lock:
            self._rebuild()
        self._unsubscribe = corpus.subscribe(self._on_delta)

    # -- plan resolution ----------------------------------------------------

    def _spec(self, rows: int, cols: Optional[int]) -> ProblemSpec:
        return ProblemSpec.for_query(
            rows, cols, self.corpus.l, measure=self.measure,
            t=self.corpus.t, l_blk=self.corpus.l_blk,
            compute_dtype=self.compute_dtype, clip=self.clip,
            fuse_epilogue=self.fuse_epilogue,
            max_tiles_per_pass=self.max_tiles_per_pass,
            interpret=self.interpret, mesh=self.mesh)

    def _operand(self):
        return self.corpus.operand(self.measure, self.compute_dtype)

    def _grid_block(self, u, rows, n_cols: int) -> np.ndarray:
        """One rectangular delta launch: `rows` of the prepared operand vs
        its first n_cols rows, dense, cropped to real rows."""
        plan, _ = self.plan_cache.get(self._spec(len(rows), n_cols))
        u_rows = take_operand_rows(u, jnp.asarray(np.asarray(rows, np.int64)),
                                   plan.n_pad)
        v_cols = take_operand_rows(u, slice(0, plan.col_pad), plan.col_pad)
        out = execute_plan(plan, u_rows, v_cols, sink=DenseSink(),
                           mesh=self.mesh, recovery=self.recovery)
        return np.asarray(out)[: len(rows)]

    # -- full (re)build -----------------------------------------------------

    def _rebuild(self) -> None:
        n = self.corpus.n
        plan, _ = self.plan_cache.get(self._spec(n, None))
        u = self._operand()
        if self.k is None:
            # own the buffer: device-backed views are read-only and the
            # standing matrix takes in-place delta merges
            self._r = np.array(execute_plan(
                plan, u, sink=DenseSink(), mesh=self.mesh,
                recovery=self.recovery), dtype=np.float32)
        else:
            top = execute_plan(plan, u, sink=TopKSink(self.k),
                               mesh=self.mesh, recovery=self.recovery)
            self._vals = np.array(top["values"], dtype=np.float32)
            self._idx = np.array(top["indices"], dtype=np.int64)
        self._generation = self.corpus.generation
        self.rebuilds += 1

    def rebuild(self) -> None:
        """Force a cold full rebuild (drops all incrementally merged
        state; the result is what a cold ``corr()`` would return)."""
        with self._lock:
            self._rebuild()

    # -- delta application --------------------------------------------------

    def _on_delta(self, delta: Delta) -> None:
        with self._lock:
            if delta.generation != self._generation + 1:
                # missed or out-of-order delta (e.g. a subscriber raised
                # before us on an earlier mutation): resync exactly
                self._rebuild()
                return
            if delta.kind == "append":
                self._apply_append(delta)
            else:
                self._apply_update(delta)
            self._generation = delta.generation
            self.deltas_applied += 1

    def _apply_append(self, delta: Delta) -> None:
        n0, n1 = delta.lo, delta.hi
        d = n1 - n0
        u = self._operand()
        # d-vs-n0 rectangular grid (GridWorkload) ...
        g = self._grid_block(u, np.arange(n0, n1), n0) if n0 else \
            np.zeros((d, 0), np.float32)
        # ... plus the d-vs-d triangle (TriangularWorkload) — never the
        # full (n0+d) triangle.
        plan_t, _ = self.plan_cache.get(self._spec(d, None))
        u_d = take_operand_rows(u, slice(n0, n1), plan_t.n_pad)
        tt = np.asarray(execute_plan(plan_t, u_d, sink=DenseSink(),
                                     mesh=self.mesh, recovery=self.recovery))
        if self.k is None:
            r = np.zeros((n1, n1), np.float32)
            r[:n0, :n0] = self._r
            r[n0:, :n0] = g
            r[:n0, n0:] = g.T
            r[n0:, n0:] = tt
            self._r = r
            return
        vals = np.zeros((n1, self.k), np.float32)
        idx = np.full((n1, self.k), -1, np.int64)
        vals[:n0], idx[:n0] = self._vals, self._idx
        # old rows gain the new columns; new rows gain everything they see
        new_ids = np.arange(n0, n1, dtype=np.int64)
        r_ids = np.concatenate([
            np.repeat(np.arange(n0, dtype=np.int64), d),    # g.T -> old rows
            np.repeat(new_ids, n0),                          # g -> new rows
            np.repeat(new_ids, d),                           # tt -> new rows
        ])
        c_ids = np.concatenate([
            np.tile(new_ids, n0),
            np.tile(np.arange(n0, dtype=np.int64), d),
            np.tile(new_ids, d),
        ])
        v = np.concatenate([np.asarray(g).T.reshape(-1), g.reshape(-1),
                            tt.reshape(-1)])
        keep = r_ids != c_ids  # drop the tt diagonal (self-pairs)
        topk_merge_rows(vals, idx, r_ids[keep], c_ids[keep], v[keep], self.k)
        self._vals, self._idx = vals, idx

    def _apply_update(self, delta: Delta) -> None:
        idx = np.asarray(delta.idx, np.int64)
        n = self.corpus.n
        u = self._operand()
        ru = self._grid_block(u, idx, n)        # (d, n), revised values
        if self.k is None:
            self._r[idx, :] = ru
            self._r[:, idx] = ru.T
            return
        # 1. revised rows: their whole neighbourhood recomputes from ru
        self._vals[idx], self._idx[idx] = topk_rows_from_dense(
            ru, self.k, exclude_cols=idx)
        # 2. rows whose kept set referenced a revised column: the stored
        #    value is stale and the k-th boundary may move — recompute
        #    them exactly with one more (bucketed) grid launch
        updated = np.zeros(n, bool)
        updated[idx] = True
        stale_mask = updated[np.clip(self._idx, 0, n - 1)] & (self._idx >= 0)
        stale_mask = stale_mask.any(axis=1)
        stale_mask[idx] = False
        stale = np.where(stale_mask)[0]
        if stale.size:
            rs = self._grid_block(u, stale, n)
            self._vals[stale], self._idx[stale] = topk_rows_from_dense(
                rs, self.k, exclude_cols=stale)
        # 3. everyone else only *gains* candidates at the revised columns
        rest = np.where(~stale_mask & ~updated)[0]
        if rest.size:
            d = idx.size
            r_ids = np.repeat(rest, d)
            c_ids = np.tile(idx, rest.size)
            v = np.asarray(ru, np.float32)[:, rest].T.reshape(-1)
            topk_merge_rows(self._vals, self._idx, r_ids, c_ids, v, self.k)

    # -- results ------------------------------------------------------------

    @property
    def generation(self) -> int:
        return self._generation

    def result(self) -> dict:
        """A copy of the standing result, naming its generation: dense
        indexes return {"r", "generation"}; top-k {"indices", "values",
        "generation"}."""
        with self._lock:
            if self.k is None:
                return {"r": self._r.copy(), "generation": self._generation}
            vals = self._vals.copy()
            vals[self._idx < 0] = 0.0
            return {"indices": self._idx.copy(), "values": vals,
                    "generation": self._generation}

    def stats(self) -> dict:
        return {"generation": self._generation, "rows": self.corpus.n,
                "deltas_applied": self.deltas_applied,
                "rebuilds": self.rebuilds,
                "plan_cache": self.plan_cache.stats()}

    def close(self) -> None:
        """Unsubscribe from the corpus (the standing state stays
        readable, frozen at its last generation)."""
        self._unsubscribe()

    def __enter__(self) -> "LiveIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = [
    "DEFAULT_DRIFT_BUDGET",
    "DRIFT_TOL",
    "Delta",
    "IncrementalOperand",
    "LiveIndex",
    "merge_row_moments",
    "row_moments",
    "supports_incremental",
    "topk_rows_from_dense",
]
