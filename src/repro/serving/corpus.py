"""CorpusHandle: a registered expression corpus, transformed once.

The serving workload (ROADMAP "serve corr() behind the request batching
layer") is "m probes vs the corpus": biologists query which of n corpus
genes co-express with a handful of probes (the rectangular GridWorkload
shape of core/api.py).  The corpus side of that product is *fixed* — an
(n, l) expression matrix registered once — so its per-measure row
transform (the only per-operand device work of a run, O(n·l)) and derived
statistics should be computed once and reused by every query, not re-run
per call.

A ``CorpusHandle`` owns a private :class:`~repro.core.api.TransformCache`
— the same seam ``corr()`` routes its operands through — keyed per
(measure, compute_dtype, tile alignment).  ``operand()`` returns the
prepared (transformed, narrowed, padded) device operand the batcher hands
to the executor as ``v_pad``; ``row_norms()`` exposes the per-row L2
norms of the transformed corpus (a cheap screen for degenerate rows:
pearson/cosine rows with zero variance/norm transform to zero rows and
score 0 with everything).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import measures
from repro.core.api import TransformCache
from repro.core.plan import prepare_operand_raw
from repro.kernels.pcc_tile import DEFAULT_LBLK, DEFAULT_TILE

Array = jax.Array


class CorpusHandle:
    """An (n, l) corpus registered with the serving layer.

    Holds a strong reference to the corpus device array (stable identity
    for the transform cache; the device buffer is pinned for the handle's
    lifetime) plus the cached per-measure prepared operands.  Handles are
    cheap views over the cache — build one per corpus and share it across
    servers/batchers.
    """

    def __init__(self, x, *, t: int = DEFAULT_TILE,
                 l_blk: int = DEFAULT_LBLK, cache_capacity: int = 8):
        x = jnp.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"corpus must be (n, l), got shape {x.shape}")
        self.x = x
        self.t = int(t)
        self.l_blk = int(l_blk)
        self._cache = TransformCache(capacity=cache_capacity)
        self._norms: Dict[str, Array] = {}
        self._null_chunks: Dict[tuple, Array] = {}

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def l(self) -> int:
        return self.x.shape[1]

    def _prepare(self, meas: measures.Measure, compute_dtype) -> Array:
        # the one shared preparation pipeline (plan.prepare_operand_raw):
        # serving bit-identity requires exactly what corr() would prepare
        return prepare_operand_raw(self.x, meas, compute_dtype,
                                   self.t, self.l_blk)

    def operand(self, measure: measures.MeasureLike = "pearson",
                compute_dtype=None) -> Array:
        """The prepared corpus operand for a measure — transformed,
        optionally narrowed, padded to kernel alignment — computed at most
        once per (measure, compute_dtype) and cached on device.

        Bit-identical to what ``corr(probes, corpus, measure=...)`` would
        prepare internally (same transform, same padding), so batched
        serving results match one-shot calls exactly.
        """
        meas = measures.get(measure)
        cd = None if compute_dtype is None else jnp.dtype(compute_dtype)
        return self._cache.prepared(
            self.x, meas, cd, self.t, self.l_blk,
            build=lambda: self._prepare(meas, cd))

    def row_norms(self, measure: measures.MeasureLike = "pearson") -> Array:
        """Per-row L2 norms of the transformed corpus (cached).

        For pearson/spearman/cosine the transform L2-normalises rows, so
        norms are 1 except for degenerate (constant / all-zero) rows,
        which are exactly 0 — a free validity screen for query results.
        """
        meas = measures.get(measure)
        norms = self._norms.get(meas.name)
        if norms is None:
            u = self.operand(meas)[: self.n]
            norms = jnp.sqrt(jnp.sum(
                u.astype(jnp.float32) ** 2, axis=1))
            self._norms[meas.name] = norms
        return norms

    def replica_source_for(self, plan, spec):
        """A caching replica source for significance queries against this
        corpus — the corpus's *null state*.

        ``run_significance`` (core/significance.py) rebuilds each replica
        chunk's stacked permuted-corpus operand per pass; for a served
        corpus that null state is as fixed as the corpus transform itself
        (it depends only on measure, dtype, method, B, chunking and key),
        so every edge-significance query against the same
        :class:`~repro.core.significance.PermutationSpec` reuses the
        stacks built by the first.  Returns a ``replica_source(ci, keys)``
        callable; entries are keyed by chunk index plus the full null
        identity and live for the handle's lifetime (``clear_null_state()``
        drops them — B x corpus operand device memory when fully built).

        Races are benign: two threads missing the same chunk compute
        identical stacks (the keys determine the permutations).
        """
        from repro.core.significance import key_fingerprint, replica_operand
        cd = (None if plan.compute_dtype is None
              else plan.compute_dtype.name)
        base = (plan.measure.name, cd, spec.method, spec.iterations,
                plan.replica_chunk, key_fingerprint(spec.key))

        def source(ci: int, keys_c) -> Array:
            cache_key = base + (ci,)
            stack = self._null_chunks.get(cache_key)
            if stack is None:
                stack = replica_operand(
                    plan, keys_c, method=spec.method, columns=self.x,
                    cols_prepared=self.operand(plan.measure,
                                               plan.compute_dtype))
                self._null_chunks[cache_key] = stack
            return stack

        return source

    def clear_null_state(self) -> None:
        """Drop every cached replica-chunk stack (memory pressure)."""
        self._null_chunks.clear()

    def stats(self) -> dict:
        """Transform-cache counters: `misses` is the number of corpus
        transforms actually run (the serving invariant: one per
        (measure, dtype), however many queries arrive).  `null_chunks` is
        the number of cached replica-chunk stacks (significance null
        state)."""
        out = self._cache.stats()
        out["null_chunks"] = len(self._null_chunks)
        return out

    def __repr__(self) -> str:
        return (f"CorpusHandle(n={self.n}, l={self.l}, t={self.t}, "
                f"l_blk={self.l_blk}, cached={len(self._cache)})")


def as_corpus(corpus, *, t: int = DEFAULT_TILE,
              l_blk: int = DEFAULT_LBLK) -> CorpusHandle:
    """Coerce an array or handle to a CorpusHandle (arrays register fresh;
    handles pass through, their alignment must match)."""
    if isinstance(corpus, CorpusHandle):
        if (corpus.t, corpus.l_blk) != (t, l_blk):
            raise ValueError(
                f"corpus handle alignment (t={corpus.t}, l_blk="
                f"{corpus.l_blk}) does not match requested (t={t}, "
                f"l_blk={l_blk})")
        return corpus
    if isinstance(corpus, (np.ndarray, jax.Array)) or hasattr(
            corpus, "__array__"):
        return CorpusHandle(corpus, t=t, l_blk=l_blk)
    raise TypeError(f"cannot register corpus of type {type(corpus)}")


__all__ = ["CorpusHandle", "as_corpus"]
