"""CorpusHandle: a registered expression corpus, transformed once.

The serving workload (ROADMAP "serve corr() behind the request batching
layer") is "m probes vs the corpus": biologists query which of n corpus
genes co-express with a handful of probes (the rectangular GridWorkload
shape of core/api.py).  The corpus side of that product is *fixed* — an
(n, l) expression matrix registered once — so its per-measure row
transform (the only per-operand device work of a run, O(n·l)) and derived
statistics should be computed once and reused by every query, not re-run
per call.

A ``CorpusHandle`` owns a private :class:`~repro.core.api.TransformCache`
— the same seam ``corr()`` routes its operands through — keyed per
(measure, compute_dtype, tile alignment).  ``operand()`` returns the
prepared (transformed, narrowed, padded) device operand the batcher hands
to the executor as ``v_pad``; ``row_norms()`` exposes the per-row L2
norms of the transformed corpus (a cheap screen for degenerate rows:
pearson/cosine rows with zero variance/norm transform to zero rows and
score 0 with everything).

Corpora are *live* (docs/serving.md "Live corpora & standing queries"):
``append(rows)`` and ``update(idx, rows)`` mutate the corpus in place.
For moment-form measures (pearson, cosine, covariance, dot) the prepared
operands are maintained *incrementally* — O(delta·l) transform work via
the running per-row moments of :mod:`repro.serving.live`, governed by a
``drift_budget`` of update batches before a forced exact refresh.  Rank
measures (spearman, kendall*) have no moment form: a mutation warns once
per measure and the next ``operand()`` re-transforms the full corpus
exactly — loud, never silently stale.  Every mutation bumps the corpus
``generation`` and pushes a :class:`~repro.serving.live.Delta` to
subscribers (standing indexes and server watches) on the mutating thread.
"""

from __future__ import annotations

import threading
import warnings
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import measures
from repro.core.api import TransformCache
from repro.core.plan import prepare_operand_raw
from repro.kernels.pcc_tile import DEFAULT_LBLK, DEFAULT_TILE
from repro.serving.live import DEFAULT_DRIFT_BUDGET, Delta, \
    IncrementalOperand, supports_incremental

Array = jax.Array


class CorpusHandle:
    """An (n, l) corpus registered with the serving layer.

    Holds a strong reference to the corpus device array (stable identity
    for the transform cache; the device buffer is pinned for the handle's
    lifetime) plus the cached per-measure prepared operands.  Handles are
    cheap views over the cache — build one per corpus and share it across
    servers/batchers.

    Mutations (``append``/``update``/``refresh``) serialize on an internal
    lock and run subscriber revalidation synchronously before returning;
    reads (``operand``/``row_norms``) are lock-free snapshots.
    """

    def __init__(self, x, *, t: int = DEFAULT_TILE,
                 l_blk: int = DEFAULT_LBLK, cache_capacity: int = 8,
                 drift_budget: int = DEFAULT_DRIFT_BUDGET):
        x = jnp.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"corpus must be (n, l), got shape {x.shape}")
        if drift_budget < 1:
            raise ValueError(f"drift_budget must be >= 1, got {drift_budget}")
        self.x = x
        self.t = int(t)
        self.l_blk = int(l_blk)
        self.drift_budget = int(drift_budget)
        self._cache = TransformCache(capacity=cache_capacity)
        self._norms: Dict[str, Array] = {}
        self._null_chunks: Dict[tuple, Array] = {}
        # -- live-corpus state --
        self._mu = threading.Lock()          # serializes mutations
        self._generation = 0
        self._live: Dict[tuple, IncrementalOperand] = {}
        self._served_exact: Dict[tuple, str] = {}   # key -> measure name
        self._warned: set = set()
        self._subscribers: Dict[int, Callable[[Delta], None]] = {}
        self._next_sub = 0
        self.refreshes = 0

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def l(self) -> int:
        return self.x.shape[1]

    @property
    def generation(self) -> int:
        """Corpus version: 0 at registration, +1 per append/update batch.
        Served results name the generation they answered against."""
        return self._generation

    def _prepare(self, meas: measures.Measure, compute_dtype) -> Array:
        # the one shared preparation pipeline (plan.prepare_operand_raw):
        # serving bit-identity requires exactly what corr() would prepare
        return prepare_operand_raw(self.x, meas, compute_dtype,
                                   self.t, self.l_blk)

    def operand(self, measure: measures.MeasureLike = "pearson",
                compute_dtype=None) -> Array:
        """The prepared corpus operand for a measure — transformed,
        optionally narrowed, padded to kernel alignment — computed at most
        once per (measure, compute_dtype) and cached on device.

        Bit-identical to what ``corr(probes, corpus, measure=...)`` would
        prepare internally (same transform, same padding), so batched
        serving results match one-shot calls exactly.  For moment-form
        measures the returned operand is *maintained* across mutations
        (incremental, within the drift budget); for rank measures it is
        rebuilt exactly after each mutation.
        """
        meas = measures.get(measure)
        cd = None if compute_dtype is None else jnp.dtype(compute_dtype)
        key = (meas.name, None if cd is None else cd.name)
        if supports_incremental(meas, cd):
            state = self._live.get(key)
            if state is None:
                state = IncrementalOperand(self.x, meas, cd, self.t,
                                           self.l_blk,
                                           operand=self._prepare(meas, cd))
                self._live[key] = state
            # re-enter the maintained operand through the TransformCache so
            # hit/miss accounting (and corr()'s shared seam) keeps working;
            # a post-mutation miss hands back the maintained operand — no
            # re-transform runs
            return self._cache.prepared(
                self.x, meas, cd, self.t, self.l_blk,
                build=lambda: state.operand)
        self._served_exact[key] = meas.name
        return self._cache.prepared(
            self.x, meas, cd, self.t, self.l_blk,
            build=lambda: self._prepare(meas, cd))

    # -- mutation -----------------------------------------------------------

    def _warn_exact_fallbacks(self) -> None:
        for name in set(self._served_exact.values()):
            if name not in self._warned:
                self._warned.add(name)
                warnings.warn(
                    f"corpus mutation with measure {name!r}: rank "
                    f"transforms have no incremental (moment) form, so "
                    f"the full corpus re-transforms exactly on next use "
                    f"(O(n*l), never silently stale). Expect mutation-"
                    f"heavy workloads on rank measures to pay cold-"
                    f"transform cost per batch.", stacklevel=3)

    def _maintain(self, apply_delta: Callable[[IncrementalOperand], None],
                  new_x: Array) -> None:
        """Advance every maintained operand, then enforce the drift
        budget: a state that has absorbed ``drift_budget`` moment-merged
        update batches rebuilds exactly from the new corpus."""
        for state in list(self._live.values()):
            apply_delta(state)
            if state.update_batches >= self.drift_budget:
                state.refresh(new_x)
                self.refreshes += 1

    def _finish_mutation(self, new_x: Array, delta_kind: str, **kw) -> Delta:
        self._warn_exact_fallbacks()
        self.x = new_x          # drops old id(x): exact caches invalidate
        self._norms.clear()
        self._null_chunks.clear()
        self._generation += 1
        delta = Delta(self._generation, delta_kind, **kw)
        errs = []
        for fn in list(self._subscribers.values()):
            try:
                fn(delta)
            except Exception as e:          # noqa: BLE001 — isolate subs
                errs.append(e)
        if errs:
            raise errs[0]
        return delta

    def _check_rows(self, rows) -> Array:
        rows = jnp.asarray(rows)
        if rows.ndim != 2 or rows.shape[1] != self.l:
            raise ValueError(
                f"mutation rows must be (d, {self.l}), got {rows.shape}")
        if rows.shape[0] == 0:
            raise ValueError("mutation batch is empty")
        return rows

    def append(self, rows) -> Delta:
        """Append d fresh rows.  Maintained operands extend in O(d·l)
        (batch-Welford moment seed + moment-form transform of just the
        new rows); subscribers revalidate against the delta before this
        returns.  Returns the :class:`Delta` (with the new generation)."""
        rows = self._check_rows(rows)
        with self._mu:
            n0 = self.n
            new_x = jnp.concatenate([self.x, rows.astype(self.x.dtype)])
            self._maintain(lambda st: st.append(rows), new_x)
            return self._finish_mutation(new_x, "append",
                                         lo=n0, hi=n0 + rows.shape[0])

    def update(self, idx, rows) -> Delta:
        """Replace the rows at ``idx`` (unique, in range) with ``rows``.
        Maintained operands advance by the Welford delta-merge of the
        affected rows' moments — O(d·l), counted against the drift budget
        (the merge is where f32 rounding accumulates; after the budget is
        spent the state rebuilds exactly)."""
        rows = self._check_rows(rows)
        idx = np.asarray(idx, np.int64).reshape(-1)
        if idx.size != rows.shape[0]:
            raise ValueError(
                f"idx has {idx.size} entries for {rows.shape[0]} rows")
        if idx.size != np.unique(idx).size:
            raise ValueError("update indices must be unique")
        if idx.size and (idx.min() < 0 or idx.max() >= self.n):
            raise ValueError(
                f"update indices out of range for n={self.n}")
        with self._mu:
            ji = jnp.asarray(idx)
            old_rows = self.x[ji]
            new_x = self.x.at[ji].set(rows.astype(self.x.dtype))
            self._maintain(lambda st: st.update(idx, old_rows, rows), new_x)
            return self._finish_mutation(new_x, "update", idx=idx)

    def refresh(self) -> None:
        """Force an exact rebuild of every maintained operand now (what
        the drift budget does periodically).  Afterwards each operand is
        bit-identical to a cold transform of the current corpus.  Does
        not bump the generation (the corpus *values* are unchanged);
        standing indexes repair drifted merged state with their own
        ``rebuild()``."""
        with self._mu:
            for state in list(self._live.values()):
                state.refresh(self.x)
                self.refreshes += 1
            # self.x keeps its id here (values unchanged), so cached
            # operand entries would go stale — drop them; the next
            # operand() re-enters the freshly rebuilt state
            self._cache.clear()

    def subscribe(self, fn: Callable[[Delta], None]) -> Callable[[], None]:
        """Register a delta subscriber (standing index / server watch).
        ``fn(delta)`` runs synchronously on the mutating thread after the
        corpus has advanced.  Returns an unsubscribe callable."""
        with self._mu:
            sid = self._next_sub
            self._next_sub += 1
            self._subscribers[sid] = fn

        def unsubscribe() -> None:
            with self._mu:
                self._subscribers.pop(sid, None)

        return unsubscribe

    # -- derived state ------------------------------------------------------

    def row_norms(self, measure: measures.MeasureLike = "pearson") -> Array:
        """Per-row L2 norms of the transformed corpus (cached).

        For pearson/spearman/cosine the transform L2-normalises rows, so
        norms are 1 except for degenerate (constant / all-zero) rows,
        which are exactly 0 — a free validity screen for query results.
        """
        meas = measures.get(measure)
        norms = self._norms.get(meas.name)
        if norms is None:
            u = self.operand(meas)
            u = getattr(u, "data", u)[: self.n]
            norms = jnp.sqrt(jnp.sum(
                u.astype(jnp.float32) ** 2, axis=1))
            self._norms[meas.name] = norms
        return norms

    def replica_source_for(self, plan, spec):
        """A caching replica source for significance queries against this
        corpus — the corpus's *null state*.

        ``run_significance`` (core/significance.py) rebuilds each replica
        chunk's stacked permuted-corpus operand per pass; for a served
        corpus that null state is as fixed as the corpus transform itself
        (it depends only on measure, dtype, method, B, chunking and key),
        so every edge-significance query against the same
        :class:`~repro.core.significance.PermutationSpec` reuses the
        stacks built by the first.  Returns a ``replica_source(ci, keys)``
        callable; entries are keyed by chunk index plus the full null
        identity and live for the handle's lifetime (``clear_null_state()``
        drops them — B x corpus operand device memory when fully built).
        Mutations clear them (the null state depends on the corpus rows).

        Races are benign: two threads missing the same chunk compute
        identical stacks (the keys determine the permutations).
        """
        from repro.core.significance import key_fingerprint, replica_operand
        cd = (None if plan.compute_dtype is None
              else plan.compute_dtype.name)
        base = (plan.measure.name, cd, spec.method, spec.iterations,
                plan.replica_chunk, key_fingerprint(spec.key))

        def source(ci: int, keys_c) -> Array:
            cache_key = base + (ci,)
            stack = self._null_chunks.get(cache_key)
            if stack is None:
                stack = replica_operand(
                    plan, keys_c, method=spec.method, columns=self.x,
                    cols_prepared=self.operand(plan.measure,
                                               plan.compute_dtype))
                self._null_chunks[cache_key] = stack
            return stack

        return source

    def clear_null_state(self) -> None:
        """Drop every cached replica-chunk stack (memory pressure)."""
        self._null_chunks.clear()

    def stats(self) -> dict:
        """Transform-cache counters: `misses` is the number of corpus
        transforms actually run (the serving invariant: one per
        (measure, dtype), however many queries arrive) — except that a
        maintained (live) operand re-enters the cache after a mutation as
        a "miss" that hands back the incrementally advanced operand
        without re-transforming.  `null_chunks` is
        the number of cached replica-chunk stacks (significance null
        state).  Live-corpus state rides along: generation, per-state
        drift counters, forced refresh count, subscriber count."""
        out = self._cache.stats()
        out["null_chunks"] = len(self._null_chunks)
        out["generation"] = self._generation
        out["rows"] = self.n
        out["drift_budget"] = self.drift_budget
        out["refreshes"] = self.refreshes
        out["subscribers"] = len(self._subscribers)
        out["live"] = {"/".join(str(p) for p in key): st.stats()
                       for key, st in self._live.items()}
        return out

    def __repr__(self) -> str:
        return (f"CorpusHandle(n={self.n}, l={self.l}, t={self.t}, "
                f"l_blk={self.l_blk}, gen={self._generation}, "
                f"cached={len(self._cache)})")


def as_corpus(corpus, *, t: int = DEFAULT_TILE,
              l_blk: int = DEFAULT_LBLK) -> CorpusHandle:
    """Coerce an array or handle to a CorpusHandle (arrays register fresh;
    handles pass through, their alignment must match)."""
    if isinstance(corpus, CorpusHandle):
        if (corpus.t, corpus.l_blk) != (t, l_blk):
            raise ValueError(
                f"corpus handle alignment (t={corpus.t}, l_blk="
                f"{corpus.l_blk}) does not match requested (t={t}, "
                f"l_blk={l_blk})")
        return corpus
    if isinstance(corpus, (np.ndarray, jax.Array)) or hasattr(
            corpus, "__array__"):
        return CorpusHandle(corpus, t=t, l_blk=l_blk)
    raise TypeError(f"cannot register corpus of type {type(corpus)}")


__all__ = ["CorpusHandle", "as_corpus"]
