"""QueryBatcher: coalesce small probe queries into one grid launch.

Interactive co-expression queries are small — a handful of probe profiles
against an n-gene corpus — and launching the tiled engine per query wastes
it: each launch pays kernel dispatch, pass-loop overhead, and (for novel
shapes) a trace.  Continuous-batching serving systems (Orca, PAPERS.md)
amortise exactly this by folding concurrent requests into one
hardware-shaped batch; for pairwise correlation the fold is free because
the engine's output rows are *independent* — row i of U@Vᵀ depends only on
row i of U — so stacking request slabs row-wise changes no result bit.

``execute()`` takes a list of :class:`Query` objects and serves them as a
minimal number of launches:

  1. group by (measure, output kind) — dense rows and per-row top-k need
     different sinks;
  2. per group: stack the probe slabs row-wise, bucket the stacked row
     count to a tile multiple (plan_cache.bucket_rows) and fetch the
     frozen plan from the :class:`~repro.serving.plan_cache.PlanCache`;
  3. run ONE ``execute_plan`` launch — the corpus operand comes prepared
     from the :class:`~repro.serving.corpus.CorpusHandle` cache, the slab
     goes through ``ExecutionPlan.prepare_rows`` (zero-row padding up to
     the bucket is inert);
  4. scatter per-request results back out: dense groups stream through
     :class:`~repro.core.sinks.RowBlockSink` straight into independent
     per-request arrays; top-k groups run one
     :class:`~repro.core.sinks.TopKSink` at the group's max k and each
     request takes its row range and leading k_i columns (top-k is
     prefix-stable: the first k_i of a top-k_max list ARE the top-k_i).

Results are bit-identical to per-request ``corr(probes, corpus, ...)``
calls (tests/test_serving.py pins this, ragged tile-straddling slabs
included).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import measures
from repro.core.allpairs import execute_plan
from repro.core.sinks import DeviceTopKSink, RowBlockSink, TopKSink
from repro.serving.corpus import CorpusHandle, as_corpus
from repro.serving.plan_cache import PlanCache, ProblemSpec
from repro.kernels.pcc_tile import DEFAULT_LBLK, DEFAULT_TILE

Array = jax.Array


@dataclasses.dataclass
class Query:
    """One serving request: (m, l) probe profiles vs the corpus.

    k=None returns the dense (m, n) correlation rows; an integer k returns
    the per-row top-k strongest-|r| corpus partners ({"indices", "values"}
    as TopKSink).  measure=None inherits the batcher's default.
    """

    probes: Any
    k: Optional[int] = None
    measure: Optional[measures.MeasureLike] = None

    def __post_init__(self):
        # Validation is deliberately eager and complete: a Query is usually
        # constructed inside CorrServer.submit(), and anything malformed
        # must be rejected AT THE DOOR with ValueError — once a request is
        # co-batched, its rows are stacked into one coalesced launch, and a
        # poisoned probe (NaN/Inf, object dtype) would otherwise fail or
        # corrupt every batch-mate's result.
        self.probes = jnp.asarray(self.probes)
        if self.probes.ndim != 2 or self.probes.shape[0] < 1:
            raise ValueError(
                f"probes must be (m >= 1, l), got shape {self.probes.shape}")
        if not (jnp.issubdtype(self.probes.dtype, jnp.floating)
                or jnp.issubdtype(self.probes.dtype, jnp.integer)):
            raise ValueError(
                f"probes must be real-valued (floating or integer), got "
                f"dtype {self.probes.dtype}")
        if not bool(jnp.all(jnp.isfinite(self.probes))):
            raise ValueError(
                "probes contain non-finite values (NaN/Inf); masked "
                "missing-data queries are not served through the batcher — "
                "use corr(probes, corpus, where='nan') directly")
        if self.k is not None and self.k <= 0:
            raise ValueError(f"k must be positive, got {self.k}")

    @property
    def m(self) -> int:
        return self.probes.shape[0]


@dataclasses.dataclass
class BatchInfo:
    """What one coalesced launch looked like (surfaced per request)."""

    requests: int           # queries coalesced into this launch
    rows: int               # real probe rows in the slab
    rows_bucket: int        # padded launch rows (tile multiple)
    plan_cache_hit: bool
    passes: int
    # per-rank tile occupancy of a mesh launch: element r is rank r's
    # assigned-tiles / per-device capacity (trailing ranks of a ceil
    # partition idle below 1.0).  None for local (mesh-free) launches.
    host_occupancy: Optional[tuple] = None

    @property
    def occupancy(self) -> float:
        """Real rows / launched rows — 1.0 means no padding waste."""
        return self.rows / self.rows_bucket if self.rows_bucket else 0.0


class QueryBatcher:
    """Executes query batches against one registered corpus.

    Synchronous core of the serving layer: :class:`CorrServer` owns the
    queueing/wait policy and calls ``execute()`` from its dispatcher
    thread; direct callers can use it as a batch API.
    """

    def __init__(self, corpus, *,
                 measure: measures.MeasureLike = "pearson",
                 plan_cache: Optional[PlanCache] = None,
                 t: int = DEFAULT_TILE, l_blk: int = DEFAULT_LBLK,
                 compute_dtype=None, clip: bool = True,
                 fuse_epilogue: bool = True,
                 max_tiles_per_pass: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 mesh=None):
        self.corpus: CorpusHandle = as_corpus(corpus, t=t, l_blk=l_blk)
        self.measure = measures.get(measure)
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self.t = int(t)
        self.l_blk = int(l_blk)
        self.compute_dtype = compute_dtype
        self.clip = clip
        self.fuse_epilogue = fuse_epilogue
        self.max_tiles_per_pass = max_tiles_per_pass
        self.interpret = interpret
        self.mesh = mesh

    # -- internals ----------------------------------------------------------

    def _resolve_measure(self, q: Query) -> measures.Measure:
        return self.measure if q.measure is None else measures.get(q.measure)

    def _spec(self, rows: int, meas: measures.Measure) -> ProblemSpec:
        return ProblemSpec.for_query(
            rows, self.corpus.n, self.corpus.l, measure=meas,
            t=self.t, l_blk=self.l_blk, compute_dtype=self.compute_dtype,
            clip=self.clip, fuse_epilogue=self.fuse_epilogue,
            max_tiles_per_pass=self.max_tiles_per_pass,
            interpret=self.interpret, mesh=self.mesh)

    def _launch_group(self, meas: measures.Measure, group: List[Query],
                      topk: bool):
        """One coalesced launch for queries sharing (measure, kind)."""
        slab = (group[0].probes if len(group) == 1
                else jnp.concatenate([q.probes for q in group]))
        rows = slab.shape[0]
        plan, hit = self.plan_cache.get(self._spec(rows, meas))
        u_pad = plan.prepare_rows(slab)
        v_pad = self.corpus.operand(meas, self.compute_dtype)

        bounds, lo = [], 0
        for q in group:
            bounds.append((lo, lo + q.m))
            lo += q.m

        if topk:
            kmax = max(q.k for q in group)
            # device-side epilogue when the plan supports it: only
            # O(rows * k) state crosses to the host per pass instead of
            # O(rows * n) tiles — the multi-host serving path.  Results
            # are bit-identical either way (the in-kernel merge replicates
            # the canonical topk_merge_rows order).
            sink = (DeviceTopKSink(kmax) if DeviceTopKSink.supports(plan)
                    else TopKSink(kmax))
            top = execute_plan(plan, u_pad, v_pad, sink=sink, mesh=self.mesh)
            outs = [{"indices": top["indices"][lo:hi, : q.k].copy(),
                     "values": top["values"][lo:hi, : q.k].copy()}
                    for (lo, hi), q in zip(bounds, group)]
        else:
            outs = execute_plan(plan, u_pad, v_pad,
                                sink=RowBlockSink(bounds), mesh=self.mesh)
        host_occ = None
        if self.mesh is not None:
            host_occ = tuple((hi - lo) / plan.per_dev
                             for lo, hi in plan.device_ranges)
        info = BatchInfo(requests=len(group), rows=rows,
                         rows_bucket=plan.n_rows, plan_cache_hit=hit,
                         passes=plan.n_pass, host_occupancy=host_occ)
        return outs, info

    # -- public -------------------------------------------------------------

    def execute(self, queries: List[Query]):
        """Serve a batch of queries with the fewest launches, returning
        (results, infos) aligned with the input order.  results[i] is the
        dense (m_i, n) array or the top-k dict of queries[i]; infos[i]
        describes the launch that served it."""
        for q in queries:
            if q.probes.shape[1] != self.corpus.l:
                raise ValueError(
                    f"probes have l={q.probes.shape[1]} samples, corpus "
                    f"has l={self.corpus.l}")
        groups: Dict[tuple, List[int]] = {}
        group_meas: Dict[tuple, measures.Measure] = {}
        for i, q in enumerate(queries):
            meas = self._resolve_measure(q)
            # group by measure *identity*, not name: a custom Measure
            # shadowing a registry name must not share a launch with it
            key = (id(meas), q.k is not None)
            groups.setdefault(key, []).append(i)
            group_meas[key] = meas

        results: List[Any] = [None] * len(queries)
        infos: List[Optional[BatchInfo]] = [None] * len(queries)
        for key, idxs in groups.items():
            group = [queries[i] for i in idxs]
            outs, info = self._launch_group(group_meas[key], group, key[1])
            for i, out in zip(idxs, outs):
                results[i] = out
                infos[i] = info
        return results, infos


__all__ = ["Query", "QueryBatcher", "BatchInfo"]
